"""Fault-tolerant federated rounds (PR 7): deterministic fault injection,
update validation + quorum aggregation, retry/backoff re-dispatch, and
mid-run crash recovery.

Four layers, mirroring the acceptance criteria:

  * unit: ``FaultProfile`` validation, ``FaultInjector`` draw determinism,
    ``corrupt_params`` modes, ``validate_update`` gates, ``FaultPolicy``
    backoff, ``ModelBuffer.push`` hardening, ``save_pytree`` non-finite
    refusal, and the self-describing run-state serializer round-trip;
  * zero-probability identity: a ``FaultProfile()`` with all probs 0 is
    bit-identical to ``faults=None`` on every executor route — the fault
    stream is a CHILD stream (0xFA17) of the training seed, so merely
    enabling the machinery must not perturb sampling, batching or init;
  * chaos: under crash=20% + corrupt=5%, fedavg / fedgkd / fedgkd-vote
    complete every round via quorum + retry and land within 2% of the
    fault-free accuracy;
  * recovery: kill-then-``resume=`` reproduces the uninterrupted history
    bit-for-bit, including through a torn (truncated) newest checkpoint
    and a hard ``os._exit`` mid-round in a subprocess.
"""
import dataclasses
import glob
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.checkpoint import recovery
from repro.configs.paper import TOY
from repro.core import algorithms, executor as ex, fl_loop
from repro.core.server import (FaultPolicy, ModelBuffer, first_nonfinite_path,
                               validate_update)
from repro.core.systemsim import (CORRUPT_MODES, Availability, FaultInjector,
                                  FaultProfile, SpeedProfile, SystemSim,
                                  corrupt_params, derive_fault_rng,
                                  derive_rng)
from repro.data.pipeline import ClientData, FederatedData
from repro.data.synthetic import SyntheticTabularTask

RAGGED_SIZES = (20, 45, 64, 100, 130, 150)

CHAOS = FaultProfile(crash_prob=0.2, corrupt_prob=0.05)


def _ragged_data(task, sizes=RAGGED_SIZES):
    gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
    clients = [ClientData(*gen.generate(n, seed=100 + i))
               for i, n in enumerate(sizes)]
    test_x, test_y = gen.generate(200, seed=999)
    return FederatedData(clients, test_x, test_y,
                         np.zeros((len(sizes), task.num_classes)))


@pytest.fixture(scope="module")
def tiny_setup():
    task = dataclasses.replace(TOY, n_clients=len(RAGGED_SIZES),
                               participation=1.0, batch_size=64, rounds=2,
                               local_epochs=2)
    return task, _ragged_data(task)


_REC_FIELDS = ("round", "test_acc", "test_loss", "mean_local_loss",
               "sim_time", "version", "mean_staleness", "sampled")


def _assert_histories_identical(a, b):
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        for f in _REC_FIELDS:
            assert getattr(ra, f) == getattr(rb, f), (ra.round, f)
    la = jax.tree_util.tree_leaves(a.final_params)
    lb = jax.tree_util.tree_leaves(b.final_params)
    assert all(bool(jnp.all(x == y)) for x, y in zip(la, lb))


# --- unit: profile / injector / corruption / validation ---------------------

def test_fault_profile_validates():
    with pytest.raises(ValueError):
        FaultProfile(crash_prob=-0.1)
    with pytest.raises(ValueError):
        FaultProfile(crash_prob=0.7, timeout_prob=0.4)  # sums past 1
    with pytest.raises(ValueError):
        FaultProfile(corrupt_prob=0.1, corrupt_modes=("nan", "bogus"))
    assert not FaultProfile().any
    assert FaultProfile(crash_prob=0.01).any


def test_injector_draws_are_deterministic():
    prof = FaultProfile(crash_prob=0.2, timeout_prob=0.1, corrupt_prob=0.1)
    a = FaultInjector(prof, derive_fault_rng(7))
    b = FaultInjector(prof, derive_fault_rng(7))
    seq_a = [a.draw() for _ in range(200)]
    seq_b = [b.draw() for _ in range(200)]
    assert seq_a == seq_b
    kinds = {f[0] for f in seq_a if f is not None}
    assert kinds == {"crash", "timeout", "corrupt"}
    assert a.counters == b.counters
    assert a.counters["crashes"] > 0 and a.counters["corrupt_injected"] > 0


def test_injector_zero_profile_never_fires_but_advances_stream():
    inj = FaultInjector(FaultProfile(), derive_fault_rng(0))
    assert all(inj.draw() is None for _ in range(50))
    assert inj.counters == {"crashes": 0, "timeouts": 0,
                            "corrupt_injected": 0, "host_crashes": 0}


def test_fault_stream_is_independent_of_sim_stream():
    # same entropy, different spawn keys: fault draws must not replay the
    # systemsim speed/availability stream
    from repro.core.systemsim import derive_rng
    a = derive_fault_rng(3).random(8)
    b = derive_rng(3).random(8)
    assert not np.allclose(a, b)


@pytest.mark.parametrize("mode", CORRUPT_MODES)
def test_corrupt_params_modes(mode):
    params = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}
    bad = corrupt_params(params, mode)
    ok, reason = validate_update(bad, params)
    assert not ok
    if mode in ("nan", "inf"):
        assert reason.startswith("nonfinite:")
        assert first_nonfinite_path(bad) is not None
    else:  # "huge" stays finite — only the norm gate catches it
        assert first_nonfinite_path(bad) is None
        assert reason.startswith("norm:")
    # original is untouched
    assert first_nonfinite_path(params) is None


def test_validate_update_accepts_clean_and_scales_norm_gate():
    ref = {"w": jnp.ones((4,))}
    ok, reason = validate_update({"w": jnp.ones((4,)) * 1.5}, ref)
    assert ok and reason == "ok"
    ok, reason = validate_update({"w": jnp.ones((4,)) * 1e4}, ref,
                                 max_norm_mult=10.0)
    assert not ok and reason.startswith("norm:")
    # loose gate lets the same update through
    ok, _ = validate_update({"w": jnp.ones((4,)) * 1e4}, ref,
                            max_norm_mult=1e6)
    assert ok


def test_fault_policy_backoff_caps():
    pol = FaultPolicy(backoff_base=1.0, backoff_cap=30.0)
    waits = [pol.backoff(k) for k in range(1, 8)]
    assert waits[:3] == [1.0, 2.0, 4.0]
    assert max(waits) == 30.0
    assert waits == sorted(waits)


# --- unit: ModelBuffer hardening / checkpoint refusal -----------------------

def test_model_buffer_rejects_nonfinite_push():
    buf = ModelBuffer(3)
    with pytest.raises(ValueError, match="w"):
        buf.push({"w": jnp.array([1.0, jnp.nan])})
    assert len(buf) == 0 and buf.versions == []


def test_model_buffer_dedups_identical_head():
    buf = ModelBuffer(3)
    p = {"w": jnp.ones((2, 2))}
    assert buf.push(p) is True
    assert buf.push({"w": jnp.ones((2, 2))}) is False  # bitwise duplicate
    assert len(buf) == 1 and buf.versions == [0]
    assert buf.push({"w": jnp.ones((2, 2)) * 1.01}) is True
    assert buf.versions == [1, 0]


def test_save_pytree_refuses_nonfinite(tmp_path):
    path = str(tmp_path / "bad.npz")
    with pytest.raises(ValueError, match="a/b"):
        ckpt_io.save_pytree(path, {"a": {"b": np.array([np.inf])}})
    assert not os.path.exists(path)


def test_run_state_roundtrip(tmp_path):
    buf = ModelBuffer(2)
    buf.push({"w": jnp.arange(4.0)})
    buf.push({"w": jnp.arange(4.0) * 2})
    rng = np.random.default_rng(5)
    rng.random(17)  # advance so the 128-bit PCG64 words are nontrivial
    state = {"buffer": buf, "np_rng": recovery.rng_state(rng),
             "records": [{"round": 0, "sampled": (1, 2, 3), "acc": 0.5}],
             "client_states": [(), {"c": jnp.ones((2,))}], "none": None}
    recovery.save_run_state(str(tmp_path), 4, state, meta={"algo": "fedgkd"})
    got, meta, rnd = recovery.load_latest_state(str(tmp_path))
    assert rnd == 4 and meta["algo"] == "fedgkd"
    buf2 = got["buffer"]
    assert buf2.versions == buf.versions and len(buf2) == 2
    assert bool(jnp.all(buf2.models[0]["w"] == buf.models[0]["w"]))
    assert got["records"][0]["sampled"] == (1, 2, 3)
    assert got["client_states"][0] == ()
    fresh = np.random.default_rng(0)
    recovery.restore_rng(fresh, got["np_rng"])
    assert fresh.random() == rng.random()


# --- zero-probability identity across every route ---------------------------

@pytest.mark.parametrize("spec", ["sequential", "vmap", "shard_map", "async"])
def test_zero_prob_faults_bit_identical(tiny_setup, spec):
    """Enabling the fault machinery with all probabilities at zero must not
    change a single bit of the run: the injector draws from its own child
    stream and a zero profile skips even those draws' side effects."""
    task, data = tiny_setup

    def route():
        if spec == "async":
            return ex.AsyncExecutor(buffer_size=3, staleness="fedgkd")
        return spec

    base = fl_loop.run_federated(task, algorithms.make("fedgkd"), data,
                                 seed=0, executor=route())
    gated = fl_loop.run_federated(task, algorithms.make("fedgkd"), data,
                                  seed=0, executor=route(),
                                  faults=FaultProfile())
    _assert_histories_identical(base, gated)
    assert gated.telemetry["faults"]["crashes"] == 0


def test_faults_identical_across_sync_routes(tiny_setup):
    """The injector fires at the fl_loop boundary in cohort order, so the
    SAME clients crash/corrupt no matter which sync executor runs the
    round — fault telemetry must match exactly."""
    task, data = tiny_setup
    out = {}
    for spec in ("sequential", "vmap"):
        h = fl_loop.run_federated(task, algorithms.make("fedavg"), data,
                                  seed=11, rounds=4, executor=spec,
                                  faults=CHAOS)
        out[spec] = h
    ta = out["sequential"].telemetry["faults"]
    tb = out["vmap"].telemetry["faults"]
    assert ta == tb
    assert ta["crashes"] + ta["corrupt_injected"] > 0
    for ra, rb in zip(out["sequential"].records, out["vmap"].records):
        assert ra.sampled == rb.sampled
        assert abs(ra.test_acc - rb.test_acc) < 1e-5


# --- chaos: quorum + retry keep every round alive ---------------------------

@pytest.mark.parametrize("name,kw", [("fedavg", {}),
                                     ("fedgkd", {"buffer_m": 3}),
                                     ("fedgkd-vote", {"buffer_m": 3})])
def test_chaos_completes_within_two_percent(tiny_setup, name, kw):
    """crash=20% + corrupt=5%: every round completes via quorum + retry and
    final accuracy stays within 2% of the fault-free run."""
    task, data = tiny_setup
    clean = fl_loop.run_federated(task, algorithms.make(name, **kw), data,
                                  seed=3, rounds=8, executor="vmap")
    fault = fl_loop.run_federated(task, algorithms.make(name, **kw), data,
                                  seed=3, rounds=8, executor="vmap",
                                  faults=CHAOS)
    assert len(fault.records) == 8
    ftel = fault.telemetry["faults"]
    assert ftel["skipped_rounds"] == 0, "quorum+retry must keep rounds alive"
    assert ftel["crashes"] > 0
    # validation gate caught every injected corruption
    assert (ftel["rejected_nonfinite"] + ftel["rejected_norm"]
            == ftel["corrupt_injected"])
    assert fault.records[-1].test_acc >= clean.records[-1].test_acc - 0.02


def test_total_crash_skips_rounds_and_holds_global(tiny_setup):
    """crash_prob=1.0 with max_retries exhausted: no survivors, the round is
    recorded as skipped and the global model is held, not zeroed."""
    task, data = tiny_setup
    h = fl_loop.run_federated(
        task, algorithms.make("fedavg"), data, seed=0, rounds=2,
        executor="sequential", faults=FaultProfile(crash_prob=1.0),
        fault_policy=FaultPolicy(max_retries=1))
    ftel = h.telemetry["faults"]
    assert ftel["skipped_rounds"] == 2
    assert ftel["quorum_shortfalls"] == 2
    assert first_nonfinite_path(h.final_params) is None
    # both rounds evaluated the held (initial) global: identical accuracy
    assert h.records[0].test_acc == h.records[1].test_acc


def test_async_chaos_completes(tiny_setup):
    task, data = tiny_setup
    h = fl_loop.run_federated(
        task, algorithms.make("fedgkd", buffer_m=3), data, seed=4, rounds=5,
        executor=ex.AsyncExecutor(buffer_size=3, staleness="fedgkd"),
        faults=CHAOS)
    assert len(h.records) == 5
    ftel = h.telemetry["faults"]
    assert ftel["crashes"] + ftel["corrupt_injected"] > 0
    assert (ftel["rejected_nonfinite"] + ftel["rejected_norm"]
            == ftel["corrupt_injected"])
    assert np.isfinite(h.records[-1].test_acc)


def test_corrupt_teacher_never_reaches_buffer(tiny_setup):
    """High corruption + a norm gate: ModelBuffer versions advance only on
    validated pushes — a quarantined update can never version-bump."""
    task, data = tiny_setup
    h = fl_loop.run_federated(
        task, algorithms.make("fedgkd", buffer_m=3), data, seed=2, rounds=4,
        executor="vmap", faults=FaultProfile(corrupt_prob=0.4))
    assert first_nonfinite_path(h.final_params) is None
    assert all(np.isfinite(r.test_loss) for r in h.records)


# --- recovery: kill then resume, bit-for-bit --------------------------------

def test_resume_reproduces_history_bit_for_bit(tiny_setup, tmp_path):
    task, data = tiny_setup
    mk = lambda: algorithms.make("fedgkd-vote", buffer_m=3)  # noqa: E731
    full = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                 executor="vmap")
    ck = str(tmp_path / "ck")
    fl_loop.run_federated(task, mk(), data, seed=9, rounds=3, executor="vmap",
                          checkpoint_dir=ck)  # the "killed" prefix
    resumed = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                    executor="vmap", checkpoint_dir=ck,
                                    resume=True)
    _assert_histories_identical(full, resumed)


def test_resume_with_faults_bit_for_bit(tiny_setup, tmp_path):
    """The fault-injector rng is checkpointed too: a resumed chaotic run
    replays the SAME crash/corrupt schedule the uninterrupted run saw."""
    task, data = tiny_setup
    mk = lambda: algorithms.make("fedgkd", buffer_m=3)  # noqa: E731
    full = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                 executor="vmap", faults=CHAOS)
    ck = str(tmp_path / "ck")
    fl_loop.run_federated(task, mk(), data, seed=9, rounds=3, executor="vmap",
                          faults=CHAOS, checkpoint_dir=ck)
    resumed = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                    executor="vmap", faults=CHAOS,
                                    checkpoint_dir=ck, resume=True)
    _assert_histories_identical(full, resumed)
    assert (full.telemetry["faults"]["crashes"]
            == resumed.telemetry["faults"]["crashes"])


def test_resume_skips_torn_checkpoint(tiny_setup, tmp_path):
    """A file torn by a crash mid-save is skipped newest-first; resume
    restarts from the previous valid round and still matches."""
    task, data = tiny_setup
    mk = lambda: algorithms.make("fedavg")  # noqa: E731
    full = fl_loop.run_federated(task, mk(), data, seed=9, rounds=5,
                                 executor="vmap")
    ck = str(tmp_path / "ck")
    fl_loop.run_federated(task, mk(), data, seed=9, rounds=3, executor="vmap",
                          checkpoint_dir=ck)
    newest = sorted(glob.glob(os.path.join(ck, "state_*.npz")))[-1]
    with open(newest, "r+b") as f:
        f.truncate(64)
    resumed = fl_loop.run_federated(task, mk(), data, seed=9, rounds=5,
                                    executor="vmap", checkpoint_dir=ck,
                                    resume=True)
    _assert_histories_identical(full, resumed)


def test_resume_fresh_dir_starts_from_scratch(tiny_setup, tmp_path):
    task, data = tiny_setup
    ck = str(tmp_path / "empty")
    os.makedirs(ck)
    h = fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              rounds=2, executor="vmap", checkpoint_dir=ck,
                              resume=True)
    assert len(h.records) == 2
    assert glob.glob(os.path.join(ck, "state_*.npz"))


def test_resume_guards(tiny_setup, tmp_path):
    task, data = tiny_setup
    with pytest.raises(ValueError, match="checkpoint_dir"):
        fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                              rounds=1, executor="vmap", resume=True)
    # checkpoint_dir= with executor="async" is no longer refused: the sim
    # heap serializes (see the async_resume suite below)
    hist = fl_loop.run_federated(task, algorithms.make("fedavg"), data,
                                 seed=0, rounds=1, executor="async",
                                 checkpoint_dir=str(tmp_path / "ok"))
    assert len(hist.records) == 1


def test_algo_mismatch_on_resume_raises(tiny_setup, tmp_path):
    task, data = tiny_setup
    ck = str(tmp_path / "ck")
    fl_loop.run_federated(task, algorithms.make("fedavg"), data, seed=0,
                          rounds=2, executor="vmap", checkpoint_dir=ck)
    with pytest.raises(ValueError, match="fedavg"):
        fl_loop.run_federated(task, algorithms.make("fedgkd", buffer_m=3),
                              data, seed=0, rounds=3, executor="vmap",
                              checkpoint_dir=ck, resume=True)


_KILL_SCRIPT = """\
import dataclasses, os, sys
import numpy as np
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.data.pipeline import ClientData, FederatedData
from repro.data.synthetic import SyntheticTabularTask

SIZES = (20, 45, 64, 100, 130, 150)
task = dataclasses.replace(TOY, n_clients=len(SIZES), participation=1.0,
                           batch_size=64, rounds=2, local_epochs=2)
gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
clients = [ClientData(*gen.generate(n, seed=100 + i))
           for i, n in enumerate(SIZES)]
tx, ty = gen.generate(200, seed=999)
data = FederatedData(clients, tx, ty, np.zeros((len(SIZES),
                                                task.num_classes)))

def kill_at_3(rnd, server, model):
    if rnd == 3:        # three rounds checkpointed, then a SIGKILL-like death
        os._exit(17)

fl_loop.run_federated(task, algorithms.make("fedgkd", buffer_m=3), data,
                      seed=9, rounds=6, executor="vmap",
                      checkpoint_dir=sys.argv[1], round_callback=kill_at_3)
"""


@pytest.mark.slow
def test_hard_kill_then_resume_matches_uninterrupted(tiny_setup, tmp_path):
    """Kill a checkpointing run with os._exit (no teardown, like OOM/SIGKILL)
    after round 3, resume in-process, and demand bit-identity with the
    never-killed run."""
    task, data = tiny_setup
    ck = str(tmp_path / "ck")
    os.makedirs(ck)
    script = tmp_path / "killed_run.py"
    script.write_text(_KILL_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in (env.get("PYTHONPATH", ""),) if p]
        + [os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")])
    proc = subprocess.run([sys.executable, str(script), ck], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 17, proc.stderr[-2000:]
    assert glob.glob(os.path.join(ck, "state_*.npz"))

    mk = lambda: algorithms.make("fedgkd", buffer_m=3)  # noqa: E731
    full = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                 executor="vmap")
    resumed = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                    executor="vmap", checkpoint_dir=ck,
                                    resume=True)
    _assert_histories_identical(full, resumed)


# --- async + population resume ----------------------------------------------
# run by name in the CI fast job: pytest tests/test_faults.py -k async_resume


def _async_exec():
    return ex.AsyncExecutor(buffer_size=3, staleness="fedgkd",
                            staleness_a=0.5, staleness_cutoff=4,
                            profile=SpeedProfile(kind="straggler",
                                                 straggler_frac=0.25),
                            availability=Availability(period=24.0, duty=0.8),
                            inner="vmap")


def _inmem_population(data, state_dir=None):
    from repro.population import Population
    from repro.population.sources import InMemorySource
    return Population(InMemorySource(data.clients), data.test_x, data.test_y,
                      state_warm_cap=3, state_dir=state_dir)


def test_async_resume_sim_state_roundtrip(tmp_path):
    """The event heap (tagged upload pytrees included), clock, dispatch
    sequence and speed/phase draws serialize through checkpoint.recovery
    and rehydrate into an identical pop order — with float64 completion
    times intact (np scalars must NOT round-trip through jnp's float32)."""
    mk = lambda: SystemSim(  # noqa: E731
        6, profile=SpeedProfile(kind="straggler", straggler_frac=0.25),
        availability=Availability(period=10.0, duty=0.5), rng=derive_rng(3))
    sim = mk()
    for k in range(6):
        sim.dispatch(k, 10 + 3 * k, tag={
            "upload": {"params": jnp.arange(3.0) + k},
            "weight": np.float64(1.5 + k), "loss": float(k),
            "version": k % 2,
            "fault": None if k % 2 else ("corrupt", CORRUPT_MODES[0])})
    sim.pop()                       # mid-wave: one completion consumed
    recovery.save_run_state(str(tmp_path), 1, {"sim": sim.state(),
                                               "in_flight": [1, 2, 3, 4, 5]})
    state, _meta, rnd = recovery.load_latest_state(str(tmp_path))
    assert rnd == 1 and state["in_flight"] == [1, 2, 3, 4, 5]
    other = mk()
    other.restore(state["sim"])
    assert other.now == sim.now and other.in_flight == sim.in_flight
    while sim.in_flight:
        a, b = sim.pop(), other.pop()
        assert (a.time, a.seq, a.client) == (b.time, b.seq, b.client)
        assert a.tag["weight"] == b.tag["weight"]
        assert a.tag["fault"] == b.tag["fault"]
        assert np.array_equal(np.asarray(a.tag["upload"]["params"]),
                              np.asarray(b.tag["upload"]["params"]))


def _check_async_resume(task, data, ck, *, faults=None, population=None,
                        rounds=8, cut=3):
    """Full run vs checkpoint-at-``cut``-then-resume: bit-identical."""
    mk = lambda: algorithms.make("fedgkd", buffer_m=3)  # noqa: E731
    pop = population() if population else None
    full = fl_loop.run_federated(task, mk(), None if pop else data,
                                 population=pop, seed=9, rounds=rounds,
                                 executor=_async_exec(), faults=faults)
    pop = population() if population else None
    fl_loop.run_federated(task, mk(), None if pop else data, population=pop,
                          seed=9, rounds=cut, executor=_async_exec(),
                          faults=faults, checkpoint_dir=ck)
    pop = population() if population else None
    resumed = fl_loop.run_federated(task, mk(), None if pop else data,
                                    population=pop, seed=9, rounds=rounds,
                                    executor=_async_exec(), faults=faults,
                                    checkpoint_dir=ck, resume=True)
    _assert_histories_identical(full, resumed)


def test_async_resume_bit_identical(tiny_setup, tmp_path):
    """Resume mid-run with executor="async": the restored heap/clock/
    in-flight fleet replay the uninterrupted history bit-for-bit."""
    task, data = tiny_setup
    _check_async_resume(task, data, str(tmp_path / "ck"))


def test_async_resume_with_faults_bit_identical(tiny_setup, tmp_path):
    """Same, under CHAOS: fault draws, retry backoff state and corrupt
    uploads (applied at fill time, never stored in the heap) all resume."""
    task, data = tiny_setup
    _check_async_resume(task, data, str(tmp_path / "ck"), faults=CHAOS)


def test_async_resume_with_population_bit_identical(tiny_setup, tmp_path):
    """checkpoint_dir= composes with population= AND executor="async":
    the state store snapshots warm-by-value/spill-by-reference and
    restored in-flight clients re-pin their warm entries."""
    task, data = tiny_setup
    sd = str(tmp_path / "spill")
    _check_async_resume(task, data, str(tmp_path / "ck"),
                        population=lambda: _inmem_population(data, sd))


def test_population_sync_resume_bit_identical(tiny_setup, tmp_path):
    """The lifted population+checkpoint refusal, sync path: a stateful
    algorithm's warm/spilled client states survive the round trip."""
    task, data = tiny_setup
    sd = str(tmp_path / "spill")
    ck = str(tmp_path / "ck")
    mk = lambda: algorithms.make("scaffold")  # noqa: E731
    full = fl_loop.run_federated(
        task, mk(), population=_inmem_population(data, sd), seed=4,
        rounds=6, executor="vmap")
    fl_loop.run_federated(
        task, mk(), population=_inmem_population(data, sd), seed=4,
        rounds=3, executor="vmap", checkpoint_dir=ck)
    resumed = fl_loop.run_federated(
        task, mk(), population=_inmem_population(data, sd), seed=4,
        rounds=6, executor="vmap", checkpoint_dir=ck, resume=True)
    _assert_histories_identical(full, resumed)


_ASYNC_KILL_SCRIPT = """\
import dataclasses, os, sys
import numpy as np
from repro.configs.paper import TOY
from repro.core import algorithms, fl_loop
from repro.core.executor import AsyncExecutor
from repro.core.systemsim import Availability, SpeedProfile
from repro.data.pipeline import ClientData, FederatedData
from repro.data.synthetic import SyntheticTabularTask

SIZES = (20, 45, 64, 100, 130, 150)
task = dataclasses.replace(TOY, n_clients=len(SIZES), participation=1.0,
                           batch_size=64, rounds=2, local_epochs=2)
gen = SyntheticTabularTask(task.num_classes, dim=task.feat_dim, seed=0)
clients = [ClientData(*gen.generate(n, seed=100 + i))
           for i, n in enumerate(SIZES)]
tx, ty = gen.generate(200, seed=999)
data = FederatedData(clients, tx, ty, np.zeros((len(SIZES),
                                                task.num_classes)))

def kill_at_3(rnd, server, model):
    if rnd == 3:    # three aggregations checkpointed, then a hard death
        os._exit(17)

fl_loop.run_federated(
    task, algorithms.make("fedgkd", buffer_m=3), data, seed=9, rounds=6,
    executor=AsyncExecutor(buffer_size=3, staleness="fedgkd",
                           staleness_a=0.5, staleness_cutoff=4,
                           profile=SpeedProfile(kind="straggler",
                                                straggler_frac=0.25),
                           availability=Availability(period=24.0, duty=0.8),
                           inner="vmap"),
    checkpoint_dir=sys.argv[1], round_callback=kill_at_3)
"""


def test_async_resume_after_hard_kill(tiny_setup, tmp_path):
    """os._exit mid-async-run (in-flight wave on the heap), resume
    in-process, demand bit-identity with the never-killed run."""
    task, data = tiny_setup
    ck = str(tmp_path / "ck")
    os.makedirs(ck)
    script = tmp_path / "killed_async_run.py"
    script.write_text(_ASYNC_KILL_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in (env.get("PYTHONPATH", ""),) if p]
        + [os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")])
    proc = subprocess.run([sys.executable, str(script), ck], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 17, proc.stderr[-2000:]
    assert glob.glob(os.path.join(ck, "state_*.npz"))

    mk = lambda: algorithms.make("fedgkd", buffer_m=3)  # noqa: E731
    full = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                 executor=_async_exec())
    resumed = fl_loop.run_federated(task, mk(), data, seed=9, rounds=6,
                                    executor=_async_exec(),
                                    checkpoint_dir=ck, resume=True)
    _assert_histories_identical(full, resumed)


# --- nightly chaos sweep (--runslow) ----------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("crash", [0.0, 0.1, 0.2])
@pytest.mark.parametrize("corrupt", [0.0, 0.05])
def test_chaos_grid(tiny_setup, crash, corrupt):
    """Nightly grid: every (crash, corrupt) cell completes all rounds with a
    finite, above-chance accuracy and fully-accounted corruption."""
    task, data = tiny_setup
    prof = FaultProfile(crash_prob=crash, corrupt_prob=corrupt)
    h = fl_loop.run_federated(task, algorithms.make("fedgkd", buffer_m=3),
                              data, seed=5, rounds=6, executor="vmap",
                              faults=prof)
    assert len(h.records) == 6
    ftel = h.telemetry["faults"]
    assert ftel["skipped_rounds"] == 0
    assert (ftel["rejected_nonfinite"] + ftel["rejected_norm"]
            == ftel["corrupt_injected"])
    assert h.records[-1].test_acc > 1.5 / task.num_classes
