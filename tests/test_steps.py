"""launch.steps: loss semantics (KD modes, frontend offsets, MTP), and the
cached-top-k KD approximation quality."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import distillation as D
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.optim import sgd


def _setup(arch="phi4-mini-3.8b"):
    cfg = configs.get_smoke_config(arch)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    teacher = transformer.init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                                     cfg.vocab_size),
    }
    return cfg, params, teacher, batch


def test_kd_none_equals_pure_ce():
    cfg, params, teacher, batch = _setup()
    l_none = steps_lib.make_loss_fn(cfg, kd_mode="none")
    l_teacher = steps_lib.make_loss_fn(cfg, kd_mode="teacher", gamma=0.0)
    a, _ = l_none(params, (), batch)
    b, _ = l_teacher(params, teacher, batch)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_kd_teacher_term_positive_for_different_teacher():
    cfg, params, teacher, batch = _setup()
    loss_fn = steps_lib.make_loss_fn(cfg, kd_mode="teacher", gamma=0.2)
    _, m = loss_fn(params, teacher, batch)
    assert float(m["kd"]) > 0
    # self-distillation (teacher == student) gives ~0 KD
    _, m0 = loss_fn(params, params, batch)
    assert abs(float(m0["kd"])) < 1e-5


def test_kd_topk_converges_to_full_kl():
    """cached_topk with K == V must equal the full KL exactly."""
    cfg, params, teacher, batch = _setup()
    t_logits, _ = transformer.forward(teacher, cfg, batch["tokens"])
    s_logits, _ = transformer.forward(params, cfg, batch["tokens"])
    v = cfg.vocab_size
    vals, idx = jax.lax.top_k(t_logits, v)
    kl_sparse = steps_lib.kd_topk_kl(vals, idx, s_logits)
    kl_full = D.kl_divergence(t_logits, s_logits)
    np.testing.assert_allclose(np.asarray(kl_sparse), np.asarray(kl_full),
                               rtol=1e-4, atol=1e-5)


def test_kd_topk_good_approximation_at_small_k():
    """Top-64 of ~500 must capture the KD signal within a few percent."""
    cfg, params, teacher, batch = _setup()
    t_logits, _ = transformer.forward(teacher, cfg, batch["tokens"])
    s_logits, _ = transformer.forward(params, cfg, batch["tokens"])
    vals, idx = jax.lax.top_k(t_logits, 64)
    kl_sparse = float(jnp.mean(steps_lib.kd_topk_kl(vals, idx, s_logits)))
    kl_full = float(jnp.mean(D.kl_divergence(t_logits, s_logits)))
    assert abs(kl_sparse - kl_full) / max(kl_full, 1e-9) < 0.25, \
        (kl_sparse, kl_full)


def test_frontend_text_offset_masks_prefix():
    cfg = configs.get_smoke_config("llava-next-34b")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    b, s_text = 2, 10
    fl = cfg.frontend_seq
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s_text), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (b, s_text), 0,
                                     cfg.vocab_size),
        "frontend_embeddings": jax.random.normal(
            jax.random.PRNGKey(4), (b, fl, cfg.d_model), cfg.adtype),
    }
    loss_fn = steps_lib.make_loss_fn(cfg, kd_mode="none")
    loss, m = loss_fn(params, (), batch)
    # manual check: CE over the text slice only
    logits, _ = transformer.forward(params, cfg, batch["tokens"],
                                    prefix_embeddings=batch["frontend_embeddings"])
    want = D.cross_entropy(logits[:, fl:], batch["labels"])
    np.testing.assert_allclose(float(m["ce"]), float(want), rtol=1e-6)


def test_mtp_loss_included_for_deepseek():
    cfg = configs.get_smoke_config("deepseek-v3-671b")
    assert cfg.mtp_depth == 1
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                     cfg.vocab_size),
    }
    loss_fn = steps_lib.make_loss_fn(cfg, kd_mode="none")
    loss, m = loss_fn(params, (), batch)
    assert "mtp_ce" in m and np.isfinite(float(m["mtp_ce"]))
    assert float(loss) > float(m["ce"])  # aux + mtp add on top


def test_train_step_decreases_loss_on_repeated_batch():
    cfg, params, teacher, batch = _setup()
    opt = sgd(momentum=0.9)
    step = jax.jit(steps_lib.make_train_step(cfg, opt, kd_mode="teacher",
                                             gamma=0.2, lr=0.05))
    o = opt.init(params)
    first = None
    p = params
    for i in range(8):
        p, o, m = step(p, teacher, o, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first


def test_aggregate_step_weighted_mean():
    from repro.launch.steps import make_aggregate_step
    from repro.sharding import shard_map_compat
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",))
    agg = make_aggregate_step("pod")
    fn = shard_map_compat(agg, mesh, in_specs=(P(), P()), out_specs=P())
    out = fn({"w": jnp.ones((2,))}, jnp.asarray(3.0))
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)
