"""Per-kernel allclose: Pallas SSD scan vs sequential + chunked oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ops
from repro.models.ssm import ssd_chunked, ssd_reference
from proptest import sweep


def _gen(key, b, l, h, p, g, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (2, 64, 4, 8, 1, 16, 16),
    (1, 96, 2, 16, 2, 8, 32),
    (2, 128, 4, 64, 1, 128, 128),
    (1, 50, 2, 8, 1, 8, 16),        # pad path
])
def test_fwd_vs_sequential(b, l, h, p, g, n, chunk):
    x, dt, A, B, C = _gen(jax.random.PRNGKey(l), b, l, h, p, g, n)
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want = ssd_reference(x, dt, A, B, C)
    tol = 1e-3 if n >= 64 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=tol, atol=tol)


def test_final_state_matches_chunked():
    x, dt, A, B, C = _gen(jax.random.PRNGKey(7), 2, 64, 4, 8, 1, 16)
    _, st = ops.ssd_scan(x, dt, A, B, C, chunk=16)
    _, st_ref = ssd_chunked(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_gradients_match_oracle():
    x, dt, A, B, C = _gen(jax.random.PRNGKey(9), 1, 32, 2, 8, 1, 8)
    g = jax.grad(lambda x, dt: jnp.sum(
        ops.ssd_scan(x, dt, A, B, C, chunk=16)[0]), argnums=(0, 1))(x, dt)
    gr = jax.grad(lambda x, dt: jnp.sum(
        ssd_chunked(x, dt, A, B, C, chunk=16)[0]), argnums=(0, 1))(x, dt)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


@sweep(n=8)
def test_property_random_configs(rng):
    b = int(rng.integers(1, 3))
    l = int(rng.integers(2, 10)) * 8
    h = int(rng.choice([2, 4]))
    g = int(rng.choice([1, h]))
    p = int(rng.choice([8, 16]))
    n = int(rng.choice([8, 16]))
    chunk = int(rng.choice([8, 16, 32]))
    x, dt, A, B, C = _gen(jax.random.PRNGKey(int(rng.integers(1 << 30))),
                          b, l, h, p, g, n)
    y, _ = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@sweep(n=6)
def test_property_decay_bounds_state(rng):
    """With x == 0 the output must be 0 regardless of dt/A/B/C."""
    b, l, h, p, g, n = 1, 32, 2, 8, 1, 8
    _, dt, A, B, C = _gen(jax.random.PRNGKey(int(rng.integers(1 << 30))),
                          b, l, h, p, g, n)
    y, st = ops.ssd_scan(jnp.zeros((b, l, h, p)), dt, A, B, C, chunk=16)
    assert float(jnp.max(jnp.abs(y))) == 0.0
    assert float(jnp.max(jnp.abs(st))) == 0.0
