"""Optimizers (convergence on quadratics) + checkpoint roundtrips."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_latest, load_pytree, save_pytree, save_round
from repro.optim import adam, apply_updates, clip_by_global_norm, global_norm, sgd
from repro.optim.schedules import cosine_decay, warmup_cosine


def _minimize(opt, lr, steps=200):
    params = {"x": jnp.asarray([3.0, -2.0])}
    target = jnp.asarray([1.0, 1.0])
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
        upd, state = opt.update(g, state, params, lr)
        return apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    return float(jnp.max(jnp.abs(params["x"] - target)))


def test_sgd_momentum_converges():
    assert _minimize(sgd(momentum=0.9), lr=0.05) < 1e-3


def test_adam_converges():
    assert _minimize(adam(), lr=0.1) < 1e-3


def test_weight_decay_shrinks():
    opt = sgd(momentum=0.0, weight_decay=0.1)
    params = {"x": jnp.asarray([1.0])}
    state = opt.init(params)
    zero_g = {"x": jnp.asarray([0.0])}
    upd, state = opt.update(zero_g, state, params, 0.1)
    new = apply_updates(params, upd)
    assert float(new["x"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedules_shapes():
    s = cosine_decay(1.0, 100)
    assert float(s(0)) == 1.0
    assert 0.0 < float(s(100)) <= 0.11
    w = warmup_cosine(1.0, 10, 100)
    assert float(w(0)) == 0.0
    assert float(w(10)) <= 1.0
    assert float(w(5)) == 0.5


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree, meta={"round": 3})
    loaded = load_pytree(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_load_latest_round(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for r in (1, 5, 3):
        save_round(str(tmp_path), r, {"w": jnp.full((2,), float(r))})
    loaded, rnd = load_latest(str(tmp_path), tree)
    assert rnd == 5
    np.testing.assert_allclose(np.asarray(loaded["w"]), 5.0)


def test_load_latest_empty(tmp_path):
    assert load_latest(str(tmp_path / "nope"), {}) is None


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    """The tiers spill real FL payloads: int32 labels next to bf16/fp16
    model leaves must all survive the npz round-trip bit-exactly."""
    tree = {"labels": jnp.asarray([0, 3, 9, 2], jnp.int32),
            "model": {"w16": jnp.asarray([1.5, -0.25, 3.0], jnp.float16),
                      "wbf": jnp.asarray([1.0, 2.0, -0.5], jnp.bfloat16),
                      "w32": jnp.linspace(0, 1, 5, dtype=jnp.float32)},
            "count": jnp.asarray(7, jnp.int32)}
    path = os.path.join(tmp_path, "mixed.npz")
    save_pytree(path, tree)
    loaded = load_pytree(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_save_pytree_is_atomic(tmp_path):
    """No .tmp debris after a save, and a stale .tmp from a crashed writer
    is invisible to load_latest's round pattern."""
    save_round(str(tmp_path), 1, {"w": jnp.ones((2,))})
    with open(os.path.join(tmp_path, "round_000002.npz.tmp"), "wb") as f:
        f.write(b"torn mid-write")
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == [
        "round_000002.npz.tmp"]
    loaded, rnd = load_latest(str(tmp_path), {"w": jnp.zeros((2,))})
    assert rnd == 1


def test_load_latest_skips_corrupt_newest(tmp_path):
    """A truncated newest round (crash mid-save under pre-atomic writers)
    must fall back to the newest LOADABLE round, not explode."""
    tree = {"w": jnp.zeros((2,))}
    for r in (1, 2):
        save_round(str(tmp_path), r, {"w": jnp.full((2,), float(r))})
    full = os.path.join(tmp_path, "round_000003.npz")
    save_round(str(tmp_path), 3, {"w": jnp.full((2,), 3.0)})
    blob = open(full, "rb").read()
    with open(full, "wb") as f:
        f.write(blob[: len(blob) // 2])      # torn zip: BadZipFile territory
    loaded, rnd = load_latest(str(tmp_path), tree)
    assert rnd == 2
    np.testing.assert_allclose(np.asarray(loaded["w"]), 2.0)


def test_load_latest_skips_zero_byte_file(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    save_round(str(tmp_path), 4, {"w": jnp.full((2,), 4.0)})
    open(os.path.join(tmp_path, "round_000009.npz"), "wb").close()
    loaded, rnd = load_latest(str(tmp_path), tree)
    assert rnd == 4


def test_load_latest_raises_when_all_corrupt(tmp_path):
    """Every round unreadable is NOT a silent fresh start: the caller must
    see a RuntimeError naming the files so history is not discarded."""
    import pytest

    for r in (1, 2):
        with open(os.path.join(tmp_path, f"round_{r:06d}.npz"), "wb") as f:
            f.write(b"not a zip at all")
    with pytest.raises(RuntimeError, match="partial or corrupt"):
        load_latest(str(tmp_path), {"w": jnp.zeros((2,))})
